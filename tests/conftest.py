"""Shared test configuration.

``hypothesis`` is an optional dev dependency (see requirements-dev.txt).
When it is not installed we install a minimal stand-in module so the seven
property-based test modules still *collect*; every ``@given`` test then
skips at runtime instead of failing the whole collection with
``ModuleNotFoundError``.
"""
import sys
import types

import pytest


def _install_hypothesis_shim() -> None:
    class _Strategies(types.ModuleType):
        """Any strategy constructor (integers, floats, ...) -> None stub."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies("hypothesis.strategies")

    def given(*_args, **_kwargs):
        def deco(fn):
            def skipper(*a, **k):
                pytest.skip("hypothesis not installed (see requirements-dev.txt)")

            skipper.__name__ = getattr(fn, "__name__", "hypothesis_test")
            skipper.__doc__ = getattr(fn, "__doc__", None)
            return skipper

        return deco

    def settings(*_args, **_kwargs):
        # used both as @settings(...) decorator and settings(...) object
        def deco(fn):
            return fn

        return deco

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
