"""Algorithm 3 (psi) + provisioning (phi) + knowledge-base tests."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.knowledge import KnowledgeBase, relative_backlog
from repro.core.profiles import amdahl_profile
from repro.core.provisioning import ProvisioningConfig, provision
from repro.core.scheduling import ActiveJob, apply_slot, schedule
from repro.core.types import Job


def mk_active(jid, k_max=4, sigma=0.5, remaining=3.0, slack=5, queue=0):
    job = Job(job_id=jid, arrival=0, length=remaining, queue=queue, delay=slack,
              profile=amdahl_profile(1, k_max, sigma))
    return ActiveJob(job=job, remaining=remaining, slack_left=slack)


class TestSchedule:
    def test_respects_capacity(self):
        active = [mk_active(i) for i in range(10)]
        alloc = schedule(active, m_t=4, rho=0.0)
        assert sum(alloc.values()) <= 4

    def test_base_before_scaling(self):
        active = [mk_active(i) for i in range(3)]
        alloc = schedule(active, m_t=5, rho=0.0, fill_spare=True)
        # all 3 jobs must hold k_min before anyone scales
        assert len(alloc) == 3
        assert sorted(alloc.values(), reverse=True)[0] <= 3

    def test_rho_blocks_scaling(self):
        active = [mk_active(0, sigma=0.9)]
        alloc = schedule(active, m_t=4, rho=0.9)
        # marginals above k=1 are < 0.9 for sigma=0.9
        assert alloc[0] == 1

    def test_forced_jobs_bypass_rho(self):
        a = mk_active(0, slack=0)
        alloc = schedule([a], m_t=4, rho=2.0)   # rho excludes everything
        assert alloc[0] == a.job.k_min

    def test_forced_ordering_by_slack(self):
        a0 = mk_active(0, slack=0)
        a1 = mk_active(1, slack=-3)
        alloc = schedule([a0, a1], m_t=1, rho=2.0)
        assert alloc == {1: 1}

    @given(
        n=st.integers(1, 8),
        m=st.integers(0, 20),
        rho=st.floats(0.0, 1.2),
        seed=st.integers(0, 1000),
    )
    @settings(max_examples=60, deadline=None)
    def test_invariants(self, n, m, rho, seed):
        rng = np.random.default_rng(seed)
        active = [
            mk_active(i, k_max=int(rng.integers(1, 6)),
                      sigma=float(rng.uniform(0.1, 1.0)),
                      slack=int(rng.integers(-2, 10)))
            for i in range(n)
        ]
        alloc = schedule(active, m_t=m, rho=rho)
        assert sum(alloc.values()) <= max(m, 0)
        by_id = {a.job.job_id: a for a in active}
        for jid, k in alloc.items():
            assert by_id[jid].job.k_min <= k <= by_id[jid].job.k_max

    def test_apply_slot_progress_and_waiting(self):
        a = mk_active(0, remaining=2.0)
        b = mk_active(1, remaining=2.0, slack=3)
        apply_slot([a, b], {0: 1})
        assert a.remaining == 1.0 and a.started
        assert b.slack_left == 2 and b.waited == 1


class TestKnowledgeBase:
    def _mk_kb(self, n=50, seed=0, **kw):
        rng = np.random.default_rng(seed)
        states = rng.normal(size=(n, 11))  # 3 CI + 2 ratio + 3 q + 3 arr... layout-free
        states = np.abs(states)
        kb = KnowledgeBase(**kw)
        kb.add_window(states, rng.integers(0, 100, n), rng.uniform(0, 1, n))
        return kb, states

    def test_exact_match_distance_zero(self):
        kb, states = self._mk_kb()
        m, rho, d = kb.query(states[7], k=1)
        assert d[0] < 1e-6

    def test_query_k_items_sorted(self):
        kb, states = self._mk_kb()
        m, rho, d = kb.query(states[0] + 0.01, k=5)
        assert len(m) == len(rho) == len(d) == 5
        assert (np.diff(d) >= -1e-12).all()

    def test_aging_drops_old_windows(self):
        kb = KnowledgeBase(max_windows=2)
        for i in range(4):
            kb.add_window(np.full((10, 11), float(i)), np.full(10, i), np.ones(10))
        assert len(kb) == 20
        m, _, _ = kb.query(np.full(11, 0.0), k=20)
        assert set(np.unique(m)) == {2.0, 3.0}

    def test_backends_agree(self):
        kb_j, states = self._mk_kb(backend="jax")
        kb_n, _ = self._mk_kb(backend="numpy")
        q = states[3] + 0.05
        mj, rj, dj = kb_j.query(q, k=4)
        mn, rn, dn = kb_n.query(q, k=4)
        np.testing.assert_allclose(np.sort(dj), np.sort(dn), rtol=1e-5)
        np.testing.assert_allclose(np.sort(mj), np.sort(mn), rtol=1e-6)

    def test_relative_backlog(self):
        r = relative_backlog(np.array([10.0, 10.0, 20.0]))
        np.testing.assert_allclose(r, [1.0, 1.0, 1.5])


class TestProvisioning:
    def _kb(self):
        kb = KnowledgeBase()
        states = np.tile(np.arange(10, dtype=float)[:, None], (1, 11))
        kb.add_window(states, np.arange(10) * 10.0, np.full(10, 0.5))
        return kb

    def test_mean_path(self):
        kb = self._kb()
        m, rho = provision(np.full(11, 2.0), kb, capacity=100, current_m=0,
                           violation_rate=0.0)
        assert 0 <= m <= 100
        assert 0 <= rho <= 1.0

    def test_violation_fallback_to_max_capacity(self):
        kb = self._kb()
        cfg = ProvisioningConfig(delta=0.0, epsilon=0.01)
        m, rho = provision(np.full(11, 100.0), kb, capacity=77, current_m=5,
                           violation_rate=0.5, cfg=cfg)
        assert m == 77 and rho == 1.0

    def test_violation_conservative_max(self):
        kb = self._kb()
        cfg = ProvisioningConfig(delta=1e9, epsilon=0.01)
        m, rho = provision(np.full(11, 2.0), kb, capacity=100, current_m=33,
                           violation_rate=0.5, cfg=cfg)
        assert m >= 33

    def test_min_required_floor(self):
        kb = self._kb()
        m, _ = provision(np.full(11, 0.0), kb, capacity=100, current_m=0,
                         violation_rate=0.0, min_required=42)
        assert m >= 42


class TestSchedulePacked:
    """schedule_packed must reproduce schedule() exactly (fill_spare=False)."""

    def _packed_world(self, n, rng):
        jobs = [
            mk_active(i, k_max=int(rng.integers(1, 6)),
                      sigma=float(rng.uniform(0.1, 1.0)),
                      slack=int(rng.integers(-3, 10)),
                      remaining=float(rng.uniform(0.5, 5)))
            for i in range(n)
        ]
        from repro.core.scheduling import EntryBlocks

        blocks = EntryBlocks.build([a.job for a in jobs])
        k_min = np.array([a.job.k_min for a in jobs], dtype=np.int64)
        slack = np.array([a.slack_left for a in jobs], dtype=np.int64)
        return jobs, blocks, k_min, slack

    @pytest.mark.parametrize("seed", range(8))
    def test_matches_dict_schedule(self, seed):
        from repro.core.scheduling import schedule_packed

        rng = np.random.default_rng(seed)
        n = int(rng.integers(1, 12))
        jobs, blocks, k_min, slack = self._packed_world(n, rng)
        m = int(rng.integers(0, 25))
        rho = float(rng.uniform(0.0, 1.2))
        want = schedule(jobs, m_t=m, rho=rho)
        kvec = schedule_packed(blocks, k_min, slack,
                               np.arange(n, dtype=np.int64), m, rho)
        got = {i: int(k) for i, k in enumerate(kvec) if k > 0}
        assert got == want, f"m={m} rho={rho}"

    def test_subset_rows(self):
        from repro.core.scheduling import schedule_packed

        rng = np.random.default_rng(42)
        jobs, blocks, k_min, slack = self._packed_world(8, rng)
        rows = np.array([1, 3, 4, 7], dtype=np.int64)
        want = schedule([jobs[r] for r in rows], m_t=6, rho=0.3)
        kvec = schedule_packed(blocks, k_min, slack, rows, 6, 0.3)
        got = {int(r): int(kvec[r]) for r in rows if kvec[r] > 0}
        # job_id == index by construction in mk_active
        assert got == want
