"""Launch-layer tests: mesh construction, cell matrix, input specs.

NOTE: these tests run on the default 1-device CPU backend; the 512-device
meshes are exercised only by the dry-run script (which sets XLA_FLAGS
before any jax import — never set globally here)."""

import jax

from repro.configs import ARCHS, LONG_CONTEXT_ARCHS
from repro.launch.dryrun import runnable
from repro.launch.mesh import make_abstract_mesh, make_mesh
from repro.models import SHAPES


class TestCellMatrix:
    def test_40_cell_grid(self):
        total = len(ARCHS) * len(SHAPES)
        assert total == 40
        live = sum(runnable(a, SHAPES[s]) for a in ARCHS for s in SHAPES)
        assert live == 32                 # 8 documented long_500k skips

    def test_long_context_archs_run_500k(self):
        for arch in LONG_CONTEXT_ARCHS:
            assert runnable(arch, SHAPES["long_500k"])
        assert not runnable("llama3-8b", SHAPES["long_500k"])
        assert not runnable("command-r-plus-104b", SHAPES["long_500k"])

    def test_all_archs_have_param_counts(self):
        from repro.models import param_count

        published = {
            "llama3-8b": 8.0e9, "command-r-plus-104b": 104e9,
            "dbrx-132b": 132e9, "qwen3-moe-235b-a22b": 235e9,
            "rwkv6-7b": 7.0e9, "zamba2-7b": 7.0e9,
            "minicpm-2b": 2.4e9, "stablelm-1.6b": 1.6e9,
            "internvl2-2b": 1.8e9,
            # musicgen-large publishes 1.5B with a 2-matrix GELU MLP; this
            # repo uses SwiGLU uniformly (+50% MLP params) -> wider bound.
            "musicgen-large": 2.4e9,
        }
        for name, expect in published.items():
            got = param_count(ARCHS[name])
            assert 0.5 < got / expect < 2.0, (name, got, expect)

    def test_moe_active_params(self):
        cfg = ARCHS["qwen3-moe-235b-a22b"]
        active = cfg.active_param_count()
        assert 10e9 < active < 40e9       # ~22B active
        assert active < cfg.param_count() / 5


class TestInputSpecsSmall:
    def test_batch_specs_no_allocation(self):
        from repro.models import LogicalRules
        from repro.train import batch_specs

        mesh = make_mesh((1, 1), ("data", "model"))
        rules = LogicalRules(mesh)
        cfg = ARCHS["internvl2-2b"]
        specs = batch_specs(cfg, SHAPES["train_4k"], rules)
        assert isinstance(specs["tokens"], jax.ShapeDtypeStruct)
        assert specs["tokens"].shape == (256, 4096 - cfg.prefix_len)
        assert specs["prefix_embeds"].shape == (256, 256, cfg.d_model)

    def test_abstract_state_matches_init(self):
        from repro.configs import reduced
        from repro.models import LogicalRules
        from repro.train import abstract_state, init_state

        mesh = make_mesh((1, 1), ("data", "model"))
        rules = LogicalRules(mesh)
        cfg = reduced(ARCHS["llama3-8b"])
        ab = abstract_state(cfg, rules)
        real = init_state(cfg, jax.random.key(0))
        ab_shapes = jax.tree.map(lambda v: (v.shape, str(v.dtype)), ab)
        real_shapes = jax.tree.map(lambda v: (v.shape, str(v.dtype)), real)
        assert jax.tree.all(jax.tree.map(lambda a, b: a == b,
                                         ab_shapes, real_shapes))


class TestMeshRules:
    def test_head_fallback_minicpm(self):
        """36 heads don't divide 16 -> heads dim replicated (DESIGN.md §6)."""
        from repro.models import LogicalRules

        mesh = make_abstract_mesh((16, 16), ("data", "model"))
        rules = LogicalRules(mesh)
        spec = rules.spec("fsdp", "heads", "head_dim", dims=(2304, 36, 64))
        assert len(spec) < 2 or spec[1] is None      # heads replicated
        spec2 = rules.spec("fsdp", "heads", "head_dim", dims=(4096, 32, 128))
        assert spec2[1] == "model"                   # divisible -> sharded

    def test_spec_divisibility(self):
        from repro.models import LogicalRules

        mesh = make_mesh((1, 1), ("data", "model"))
        rules = LogicalRules(mesh)
        # divisible dims keep their mapping (trivially on a 1x1 mesh)
        s = rules.spec("batch", "seq", dims=(8, 128))
        assert s is not None
