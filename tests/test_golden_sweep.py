"""Golden regression: a tiny sweep must reproduce its checked-in JSON
exactly (ISSUE-3 satellite).

The fixture pins the full ``Sweep.to_json`` payload of a 2-region x
2-seed x 3-policy grid — per-case carbon/energy floats included — so an
engine refactor that silently shifts the EXPERIMENTS.md numbers fails
here first.  Regenerate deliberately (after verifying the shift is
intended) with:

    PYTHONPATH=src python tests/test_golden_sweep.py --regen
"""
import json
import os

from repro.experiment import Scenario, Sweep
from repro.traces import DagConfig

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "golden_sweep.json")
FIXTURE_DAG = os.path.join(os.path.dirname(__file__), "data",
                           "golden_sweep_dag.json")


def golden_sweep() -> Sweep:
    """2 regions x 2 seeds x 3 policies, no-KB policies so the grid runs
    in seconds (the engine semantics, not the learning phase, are pinned)."""
    return Sweep(
        base=Scenario(capacity=8, learn_weeks=1, family="alibaba", seed=101),
        regions=["california", "ontario"],
        seeds=[11, 12],
        policies=["carbon-agnostic", "gaia", "wait-awhile"])


def golden_dag_sweep() -> Sweep:
    """A small precedence-gated grid (ISSUE-4 satellite): 2 seeds x 3 DAG
    policies over a chain/mapreduce/layered workload — pins the
    dependency-gated engine paths and the criticality analysis."""
    return Sweep(
        base=Scenario(dag=DagConfig(width=3, depth=3), capacity=8,
                      learn_weeks=1, family="alibaba", seed=101),
        seeds=[11, 12],
        policies=["dag-fcfs", "dag-carbon", "dag-cap"])


def test_golden_sweep_reproduces_fixture_exactly():
    with open(FIXTURE) as f:
        want = json.load(f)
    got = json.loads(golden_sweep().run().to_json())
    # compare piecewise first for a readable diff on mismatch
    assert got["baseline"] == want["baseline"]
    assert len(got["rows"]) == len(want["rows"]) == 12
    for g, w in zip(got["rows"], want["rows"]):
        key = (w["region"], w["seed"], w["policy"])
        assert g == w, f"row drifted: {key}"
    assert got["summary"] == want["summary"]
    assert got == want


def test_golden_dag_sweep_reproduces_fixture_exactly():
    with open(FIXTURE_DAG) as f:
        want = json.load(f)
    got = json.loads(golden_dag_sweep().run().to_json())
    assert got["baseline"] == want["baseline"] == "dag-fcfs"
    assert len(got["rows"]) == len(want["rows"]) == 6
    for g, w in zip(got["rows"], want["rows"]):
        assert g == w, f"row drifted: {(w['seed'], w['policy'])}"
    assert got["summary"] == want["summary"]
    assert got == want


def test_dag_fixture_shape_sanity():
    with open(FIXTURE_DAG) as f:
        want = json.load(f)
    rows = want["rows"]
    assert {r["policy"] for r in rows} == {"dag-fcfs", "dag-carbon",
                                           "dag-cap"}
    assert {r["seed"] for r in rows} == {11, 12}
    assert all(r["carbon_g"] > 0 for r in rows)
    carbon = [r for r in rows if r["policy"] == "dag-carbon"]
    assert all(r["savings_pct"] > 0 for r in carbon)


def test_fixture_shape_sanity():
    with open(FIXTURE) as f:
        want = json.load(f)
    rows = want["rows"]
    assert {r["policy"] for r in rows} == {"carbon-agnostic", "gaia",
                                           "wait-awhile"}
    assert {r["region"] for r in rows} == {"california", "ontario"}
    assert {r["seed"] for r in rows} == {11, 12}
    assert all(r["carbon_g"] > 0 for r in rows)
    base = [r for r in rows if r["policy"] == "carbon-agnostic"]
    assert all(r["savings_pct"] == 0.0 for r in base)


if __name__ == "__main__":
    import argparse
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the fixture from the current engine")
    if ap.parse_args().regen:
        os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
        for path, sweep in ((FIXTURE, golden_sweep()),
                            (FIXTURE_DAG, golden_dag_sweep())):
            payload = sweep.run().to_json()
            with open(path, "w") as f:
                f.write(payload)
                f.write("\n")
            print(f"wrote {path} ({len(payload)} bytes)")
