"""Golden regression: a tiny sweep must reproduce its checked-in JSON
exactly (ISSUE-3 satellite).

The fixture pins the full ``Sweep.to_json`` payload of a 2-region x
2-seed x 3-policy grid — per-case carbon/energy floats included — so an
engine refactor that silently shifts the EXPERIMENTS.md numbers fails
here first.  Regenerate deliberately (after verifying the shift is
intended) with:

    PYTHONPATH=src python tests/test_golden_sweep.py --regen
"""
import dataclasses
import json
import os

from repro.core.forecast import (NoisyForecast, PerfectForecast,
                                 QuantileForecast)
from repro.core.mpc import MPCConfig
from repro.experiment import Scenario, ServingConfig, Sweep
from repro.traces import DagConfig

FIXTURE = os.path.join(os.path.dirname(__file__), "data", "golden_sweep.json")
FIXTURE_DAG = os.path.join(os.path.dirname(__file__), "data",
                           "golden_sweep_dag.json")
FIXTURE_FORECAST = os.path.join(os.path.dirname(__file__), "data",
                                "golden_sweep_forecast.json")
FIXTURE_SERVING = os.path.join(os.path.dirname(__file__), "data",
                               "golden_sweep_serving.json")
FIXTURE_MPC = os.path.join(os.path.dirname(__file__), "data",
                           "golden_sweep_mpc.json")


def golden_sweep() -> Sweep:
    """2 regions x 2 seeds x 3 policies, no-KB policies so the grid runs
    in seconds (the engine semantics, not the learning phase, are pinned)."""
    return Sweep(
        base=Scenario(capacity=8, learn_weeks=1, family="alibaba", seed=101),
        regions=["california", "ontario"],
        seeds=[11, 12],
        policies=["carbon-agnostic", "gaia", "wait-awhile"])


def golden_dag_sweep() -> Sweep:
    """A small precedence-gated grid (ISSUE-4 satellite): 2 seeds x 3 DAG
    policies over a chain/mapreduce/layered workload — pins the
    dependency-gated engine paths and the criticality analysis."""
    return Sweep(
        base=Scenario(dag=DagConfig(width=3, depth=3), capacity=8,
                      learn_weeks=1, family="alibaba", seed=101),
        seeds=[11, 12],
        policies=["dag-fcfs", "dag-carbon", "dag-cap"])


def golden_forecast_sweep() -> Sweep:
    """A small forecast-axis grid (ISSUE-5 satellite): perfect + noisy +
    quantile-ensemble forecasts x (plain + robust) threshold policies —
    pins the forecast subsystem's realized error streams end-to-end."""
    return Sweep(
        base=Scenario(capacity=8, learn_weeks=1, family="alibaba", seed=101),
        seeds=[11],
        policies=["carbon-agnostic", "wait-awhile", "wait-awhile-robust"],
        forecasts=[None, NoisyForecast(sigma=0.3, seed=5),
                   QuantileForecast(sigma=0.2, seed=5, members=7)])


def golden_serving_sweep() -> Sweep:
    """A small serving grid (ISSUE-7 satellite): 2 seeds x 3 serve
    policies over a diurnal request trace — pins the request-trace
    generator, the derived tier table, the credit ledger, and the serving
    engine's accounting end-to-end."""
    return Sweep(
        base=Scenario(serving=ServingConfig(requests_per_day=2e5,
                                            servers=12),
                      learn_weeks=1, eval_weeks=1, seed=101),
        seeds=[11, 12],
        policies=["serve-static", "serve-greedy", "serve-flex"])


def golden_mpc_sweep() -> Sweep:
    """A small receding-horizon grid (ISSUE-10 satellite): 2 seeds x the
    MPC policy family + the estimated oracle on the scan engine — pins
    the precomputed decision tables, the marginal-capacity scale-up
    energy replay, and the estimated-oracle plan end-to-end.  The
    explicit ``scale_rho`` forces genuinely scaled cells (the learned
    rho median licenses none on this workload), so the fixture pins the
    k > k_min energy path, not just the degenerate k_min one."""
    return Sweep(
        base=Scenario(capacity=8, learn_weeks=1, family="alibaba",
                      seed=101, engine="scan",
                      mpc=MPCConfig(scale_rho=0.3)),
        seeds=[11, 12],
        policies=["carbon-agnostic", "carbonflex-mpc", "carbonflex-scale",
                  "oracle-estimated"])


def test_golden_sweep_reproduces_fixture_exactly():
    with open(FIXTURE) as f:
        want = json.load(f)
    got = json.loads(golden_sweep().run().to_json())
    # compare piecewise first for a readable diff on mismatch
    assert got["baseline"] == want["baseline"]
    assert len(got["rows"]) == len(want["rows"]) == 12
    for g, w in zip(got["rows"], want["rows"]):
        key = (w["region"], w["seed"], w["policy"])
        assert g == w, f"row drifted: {key}"
    assert got["summary"] == want["summary"]
    assert got == want


def test_golden_dag_sweep_reproduces_fixture_exactly():
    with open(FIXTURE_DAG) as f:
        want = json.load(f)
    got = json.loads(golden_dag_sweep().run().to_json())
    assert got["baseline"] == want["baseline"] == "dag-fcfs"
    assert len(got["rows"]) == len(want["rows"]) == 6
    for g, w in zip(got["rows"], want["rows"]):
        assert g == w, f"row drifted: {(w['seed'], w['policy'])}"
    assert got["summary"] == want["summary"]
    assert got == want


def test_golden_sweeps_byte_identical_with_scan_engine():
    """ISSUE-8: ``engine="scan"`` is a pure implementation swap, so the
    scan-engine run of the batch and DAG golden grids must reproduce the
    checked-in JSON payloads byte-for-byte (rows carry no engine column;
    any float drift in the fused device path fails here)."""
    for path, mk in ((FIXTURE, golden_sweep), (FIXTURE_DAG, golden_dag_sweep)):
        with open(path) as f:
            want = f.read()
        sw = mk()
        sw = dataclasses.replace(
            sw, base=dataclasses.replace(sw.base, engine="scan"))
        assert sw.run().to_json() + "\n" == want, path


def test_golden_sweeps_byte_identical_with_recorder_attached():
    """ISSUE-9: trace recording is observation-only, so running the
    batch, DAG and serving golden grids with a recorder *and* profiler
    attached must still reproduce the checked-in JSON byte-for-byte —
    while actually recording events (an empty stream would make the
    identity vacuous)."""
    from repro.telemetry import MemoryRecorder, PhaseProfiler, Telemetry

    for path, mk in ((FIXTURE, golden_sweep), (FIXTURE_DAG, golden_dag_sweep),
                     (FIXTURE_SERVING, golden_serving_sweep)):
        with open(path) as f:
            want = f.read()
        tel = Telemetry(recorder=MemoryRecorder(), profiler=PhaseProfiler())
        sw = dataclasses.replace(mk(), telemetry=tel)
        assert sw.run().to_json() + "\n" == want, path
        assert len(tel.recorder) > 0, path
        assert tel.profiler.total() > 0, path


def test_golden_mpc_sweep_reproduces_fixture_exactly():
    with open(FIXTURE_MPC) as f:
        want = json.load(f)
    got = json.loads(golden_mpc_sweep().run().to_json())
    assert got["baseline"] == want["baseline"] == "carbon-agnostic"
    assert len(got["rows"]) == len(want["rows"]) == 8
    for g, w in zip(got["rows"], want["rows"]):
        assert g == w, f"row drifted: {(w['seed'], w['policy'])}"
    assert got["summary"] == want["summary"]
    assert got == want


def test_mpc_fixture_shape_sanity():
    with open(FIXTURE_MPC) as f:
        want = json.load(f)
    rows = want["rows"]
    assert {r["policy"] for r in rows} == {"carbon-agnostic",
                                           "carbonflex-mpc",
                                           "carbonflex-scale",
                                           "oracle-estimated"}
    assert {r["seed"] for r in rows} == {11, 12}
    assert all(r["carbon_g"] > 0 for r in rows)
    mpc = [r for r in rows if r["policy"] == "carbonflex-mpc"]
    assert all(r["savings_pct"] > 0 for r in mpc)


def test_mpc_sweep_engine_parity_with_vector():
    """The MPC golden grid is defined on the scan engine; the vector
    engine must reproduce the identical payload (the fixture pins one
    engine, this pins the other two against it transitively)."""
    sw = golden_mpc_sweep()
    sw_v = dataclasses.replace(
        sw, base=dataclasses.replace(sw.base, engine="vector"))
    assert sw_v.run().to_json() == sw.run().to_json()


def test_dag_fixture_shape_sanity():
    with open(FIXTURE_DAG) as f:
        want = json.load(f)
    rows = want["rows"]
    assert {r["policy"] for r in rows} == {"dag-fcfs", "dag-carbon",
                                           "dag-cap"}
    assert {r["seed"] for r in rows} == {11, 12}
    assert all(r["carbon_g"] > 0 for r in rows)
    carbon = [r for r in rows if r["policy"] == "dag-carbon"]
    assert all(r["savings_pct"] > 0 for r in carbon)


def test_explicit_perfect_forecast_matches_default_golden_rows():
    """Backward compat (ISSUE-5): running the golden grid with
    ``forecast=PerfectForecast()`` set *explicitly* reproduces the
    checked-in rows bit-for-bit (modulo the forecast label column the
    axis adds)."""
    with open(FIXTURE) as f:
        want = json.load(f)
    sw = golden_sweep()
    sw = dataclasses.replace(
        sw, base=dataclasses.replace(sw.base, forecast=PerfectForecast()))
    got = json.loads(sw.run().to_json())
    assert len(got["rows"]) == len(want["rows"])
    for g, w in zip(got["rows"], want["rows"]):
        assert g.pop("forecast") == "perfect"
        assert g == w, f"row drifted: {(w['region'], w['seed'], w['policy'])}"
    assert got["summary"] == want["summary"]


def test_golden_forecast_sweep_reproduces_fixture_exactly():
    with open(FIXTURE_FORECAST) as f:
        want = json.load(f)
    got = json.loads(golden_forecast_sweep().run().to_json())
    assert got["baseline"] == want["baseline"] == "carbon-agnostic"
    assert len(got["rows"]) == len(want["rows"]) == 9
    for g, w in zip(got["rows"], want["rows"]):
        key = (w["forecast"], w["policy"])
        assert g == w, f"row drifted: {key}"
    assert got["summary"] == want["summary"]
    assert got == want


def test_forecast_fixture_shape_sanity():
    with open(FIXTURE_FORECAST) as f:
        want = json.load(f)
    rows = want["rows"]
    assert {r["forecast"] for r in rows} == {"perfect", "noisy(s=0.3)",
                                             "quantile(s=0.2,m=7)"}
    assert {r["policy"] for r in rows} == {"carbon-agnostic", "wait-awhile",
                                           "wait-awhile-robust"}
    assert all(r["carbon_g"] > 0 for r in rows)
    # under the perfect forecast the robust variant is bit-identical
    perfect = {r["policy"]: r["carbon_g"] for r in rows
               if r["forecast"] == "perfect"}
    assert perfect["wait-awhile"] == perfect["wait-awhile-robust"]
    # under noise they diverge (the realized error streams differ)
    noisy = {r["policy"]: r["carbon_g"] for r in rows
             if r["forecast"] == "noisy(s=0.3)"}
    assert noisy["wait-awhile"] != noisy["wait-awhile-robust"]


def test_golden_serving_sweep_reproduces_fixture_exactly():
    with open(FIXTURE_SERVING) as f:
        want = json.load(f)
    got = json.loads(golden_serving_sweep().run().to_json())
    assert got["baseline"] == want["baseline"] == "serve-static"
    assert len(got["rows"]) == len(want["rows"]) == 6
    for g, w in zip(got["rows"], want["rows"]):
        assert g == w, f"row drifted: {(w['seed'], w['policy'])}"
    assert got["summary"] == want["summary"]
    assert got == want


def test_serving_fixture_shape_sanity():
    with open(FIXTURE_SERVING) as f:
        want = json.load(f)
    rows = want["rows"]
    assert {r["policy"] for r in rows} == {"serve-static", "serve-greedy",
                                           "serve-flex"}
    assert {r["seed"] for r in rows} == {11, 12}
    assert all(r["carbon_g"] > 0 for r in rows)
    assert all(-1.0 <= r["serving"]["ledger_min"]
               <= r["serving"]["ledger_max"] <= 1.0 for r in rows)
    flex = [r for r in rows if r["policy"] == "serve-flex"]
    assert all(r["savings_pct"] > 0 for r in flex)


def test_serving_is_additive_to_existing_fixtures():
    """Regression for the serving subsystem being purely additive: running
    a serving sweep first must leave the pre-existing batch golden rows
    byte-identical (no shared RNG stream, no global state)."""
    golden_serving_sweep().run()
    with open(FIXTURE) as f:
        want = json.load(f)
    got = json.loads(golden_sweep().run().to_json())
    assert got == want


def test_fixture_shape_sanity():
    with open(FIXTURE) as f:
        want = json.load(f)
    rows = want["rows"]
    assert {r["policy"] for r in rows} == {"carbon-agnostic", "gaia",
                                           "wait-awhile"}
    assert {r["region"] for r in rows} == {"california", "ontario"}
    assert {r["seed"] for r in rows} == {11, 12}
    assert all(r["carbon_g"] > 0 for r in rows)
    base = [r for r in rows if r["policy"] == "carbon-agnostic"]
    assert all(r["savings_pct"] == 0.0 for r in base)


if __name__ == "__main__":
    import argparse
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--regen", action="store_true",
                    help="rewrite the fixture from the current engine")
    if ap.parse_args().regen:
        os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
        for path, sweep in ((FIXTURE, golden_sweep()),
                            (FIXTURE_DAG, golden_dag_sweep()),
                            (FIXTURE_FORECAST, golden_forecast_sweep()),
                            (FIXTURE_SERVING, golden_serving_sweep()),
                            (FIXTURE_MPC, golden_mpc_sweep())):
            payload = sweep.run().to_json()
            with open(path, "w") as f:
                f.write(payload)
                f.write("\n")
            print(f"wrote {path} ({len(payload)} bytes)")
