"""Geo-distributed engine + policies: parity, semantics, API threading.

The multi-region vectorised engine must reproduce the scalar geo reference
bit-for-bit for every geo policy, with and without fault injection
(ISSUE-3 acceptance).  On top: migration accounting invariants, placement
behaviour of the three policies, and the Scenario/Sweep/registry
integration."""
import dataclasses

import numpy as np
import pytest

from repro.core import (ClusterConfig, GeoCluster, GeoFlexPolicy,
                        GeoGreedyPolicy, GeoStaticPolicy, MigrationModel,
                        MultiRegionCarbonService, simulate)
from repro.core.carbon import CarbonService
from repro.core.simulator import FaultModel, SimCase, simulate_many
from repro.core.types import Job
from repro.experiment import (DEFAULT_GEO_POLICIES, Scenario, Sweep,
                              make_policy, prepare_context, run)
from repro.traces import TraceSpec, generate_trace

WEEK = 24 * 7
REGIONS2 = ("south-australia", "california")
REGIONS3 = ("south-australia", "california", "ontario")

_MK = {"geo-static": GeoStaticPolicy, "geo-greedy": GeoGreedyPolicy,
       "geo-flex": GeoFlexPolicy}


@pytest.fixture(scope="module")
def world():
    geo = GeoCluster.split(20, REGIONS3)
    mci = MultiRegionCarbonService.synthetic(REGIONS3, WEEK * 2 + 24 * 30,
                                             seed=21)
    jobs = generate_trace(TraceSpec(family="azure", hours=WEEK, capacity=20,
                                    seed=22), geo.queues)
    return geo, mci, jobs


def assert_geo_results_identical(a, b, ctx=""):
    assert a.carbon_g == b.carbon_g, ctx
    assert a.energy_kwh == b.energy_kwh, ctx
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    np.testing.assert_array_equal(a.violations, b.violations, err_msg=ctx)
    np.testing.assert_array_equal(a.wait_slots, b.wait_slots, err_msg=ctx)
    np.testing.assert_array_equal(a.final_region, b.final_region, err_msg=ctx)
    np.testing.assert_array_equal(a.region_carbon_g, b.region_carbon_g,
                                  err_msg=ctx)
    np.testing.assert_array_equal(a.region_energy_kwh, b.region_energy_kwh,
                                  err_msg=ctx)
    assert a.migrations == b.migrations, ctx
    assert a.migration_carbon_g == b.migration_carbon_g, ctx
    assert len(a.slots) == len(b.slots), ctx
    for la, lb in zip(a.slots, b.slots):
        assert la == lb, f"{ctx}: slot {la.slot}"


# --- engine parity -----------------------------------------------------------


@pytest.mark.parametrize("policy_name", sorted(_MK))
def test_geo_engines_identical_per_policy(world, policy_name):
    geo, mci, jobs = world
    mk = _MK[policy_name]
    rs = simulate(jobs, mci, geo, mk(), horizon=WEEK, engine="scalar")
    for engine in ("vector", "scan"):
        rv = simulate(jobs, mci, geo, mk(), horizon=WEEK, engine=engine)
        assert_geo_results_identical(rs, rv, f"{policy_name}/{engine}")
        assert (rv.completion >= 0).all()
        assert set(rv.final_region.tolist()) <= set(range(geo.n_regions))


@pytest.mark.parametrize("policy_name", sorted(_MK))
@pytest.mark.parametrize("fault_seed", [2, 9])
def test_geo_engines_identical_under_faults(world, policy_name, fault_seed):
    geo, mci, jobs = world
    mk = _MK[policy_name]
    mk_faults = lambda: FaultModel(straggler_rate=0.15, failure_rate=0.05,  # noqa: E731
                                   seed=fault_seed)
    rs = simulate(jobs, mci, geo, mk(), horizon=WEEK, engine="scalar",
                  faults=mk_faults())
    for engine in ("vector", "scan"):   # scan delegates faulted cases
        rv = simulate(jobs, mci, geo, mk(), horizon=WEEK, engine=engine,
                      faults=mk_faults())
        assert_geo_results_identical(rs, rv,
                                     f"{policy_name}+faults/{engine}")


@pytest.mark.parametrize("policy_name", sorted(_MK))
@pytest.mark.parametrize("forecast", ["noisy", "quantile"])
@pytest.mark.parametrize("faulty", [False, True])
def test_geo_engines_identical_under_noisy_forecasts(world, policy_name,
                                                     forecast, faulty):
    """ISSUE-5 satellite: the multi-region engines consume per-region
    forecast error streams identically — bit-for-bit parity holds under
    NoisyForecast / QuantileForecast, with and without faults."""
    from repro.core import NoisyForecast, QuantileForecast

    geo, mci, jobs = world
    model = (NoisyForecast(sigma=0.3, seed=5) if forecast == "noisy"
             else QuantileForecast(sigma=0.3, seed=5, members=5))
    mci_f = MultiRegionCarbonService(
        mci.regions,
        tuple(dataclasses.replace(s, model=model) for s in mci.services))
    mk = _MK[policy_name]
    mk_faults = (lambda: FaultModel(straggler_rate=0.15, failure_rate=0.05,
                                    seed=3)) if faulty else (lambda: None)
    rs = simulate(jobs, mci_f, geo, mk(), horizon=WEEK, engine="scalar",
                  faults=mk_faults())
    for engine in ("vector", "scan"):
        rv = simulate(jobs, mci_f, geo, mk(), horizon=WEEK, engine=engine,
                      faults=mk_faults())
        assert_geo_results_identical(rs, rv,
                                     f"{policy_name}+{forecast}/{engine}")


def test_simulate_many_dispatches_geo_cases(world):
    geo, mci, jobs = world
    cases = [SimCase(jobs=jobs, ci=mci, cluster=geo, policy=_MK[n](),
                     horizon=WEEK, label=n) for n in sorted(_MK)]
    batch = simulate_many(cases)
    for n, r in zip(sorted(_MK), batch):
        solo = simulate(jobs, mci, geo, _MK[n](), horizon=WEEK)
        assert_geo_results_identical(solo, r, f"simulate_many/{n}")


# --- accounting & semantics --------------------------------------------------


def test_region_totals_sum_to_run_totals(world):
    geo, mci, jobs = world
    r = simulate(jobs, mci, geo, GeoFlexPolicy(), horizon=WEEK)
    assert r.migrations > 0                       # the scenario exercises moves
    assert r.migration_carbon_g > 0
    np.testing.assert_allclose(r.region_carbon_g.sum(), r.carbon_g, rtol=1e-12)
    np.testing.assert_allclose(r.region_energy_kwh.sum(), r.energy_kwh,
                               rtol=1e-12)
    assert (r.region_energy_kwh >= 0).all()
    assert r.migration_carbon_g < r.carbon_g


def test_migration_cost_model_scales_with_job_size():
    mm = MigrationModel(base_slots=1, slots_per_length=0.1,
                        energy_kwh_per_gb=0.05, min_gb=2.0)
    small = Job(job_id=0, arrival=0, length=2.0, queue=0, delay=6,
                profile=np.ones(1))
    big = Job(job_id=1, arrival=0, length=40.0, queue=0, delay=6,
              profile=np.ones(1), comm_size=8.0)
    assert mm.slots(big) > mm.slots(small) >= 1
    assert mm.energy_kwh(small) == pytest.approx(0.05 * 2.0)   # floored
    assert mm.energy_kwh(big) == pytest.approx(0.05 * 8.0)
    assert mm.carbon_g(big, 100.0) == pytest.approx(0.05 * 8.0 * 100.0)


def test_geo_static_pins_jobs_to_home_region(world):
    geo, mci, jobs = world
    r = simulate(jobs, mci, geo, GeoStaticPolicy(), horizon=WEEK)
    assert r.migrations == 0
    rows = sorted(jobs, key=lambda j: (j.arrival, j.job_id))
    expect = np.array([geo.home_region(i) for i in range(len(rows))])
    np.testing.assert_array_equal(r.final_region, expect)


def test_geo_greedy_prefers_cleaner_regions(world):
    geo, mci, jobs = world
    r = simulate(jobs, mci, geo, GeoGreedyPolicy(), horizon=WEEK)
    # greedy now migrates on instantaneous-CI profit (ISSUE-8 satellite:
    # the old sticky variant reported 0 moves by construction)
    assert r.migrations > 0
    # mean CI per region orders ontario (clean) above south-australia;
    # greedy placement must send more work to the cleaner regions than
    # the static round-robin does
    static = simulate(jobs, mci, geo, GeoStaticPolicy(), horizon=WEEK)
    mean_ci = np.array([s.trace.mean() for s in mci.services])
    cleanest = int(np.argmin(mean_ci))
    assert (r.final_region == cleanest).sum() \
        >= (static.final_region == cleanest).sum()
    assert r.carbon_g < static.carbon_g


def test_geo_greedy_migrates_on_large_ci_gap():
    """ISSUE-8 satellite regression: on a constructed two-region trace
    whose CI ranking flips hard after the job starts, geo-greedy must
    initiate a migration (the pre-fix sticky variant never could), in
    every engine, with identical accounting."""
    hours = 24 * 10
    trace_a = np.full(hours, 1000.0)
    trace_a[:2] = 1.0                  # clean at placement, filthy after
    trace_b = np.full(hours, 5.0)
    trace_b[:2] = 500.0                # dirty at placement, clean after
    mci = MultiRegionCarbonService(
        ("flip", "clean"),
        (CarbonService(trace=trace_a), CarbonService(trace=trace_b)))
    geo = GeoCluster(regions=("flip", "clean"), capacities=(4, 4),
                     queues=ClusterConfig.default(8).queues,
                     migration=MigrationModel())
    job = Job(job_id=0, arrival=0, length=10.0, queue=2, delay=48,
              profile=np.ones(1))
    results = {e: simulate([job], mci, geo, GeoGreedyPolicy(), horizon=hours,
                           engine=e) for e in ("scalar", "vector", "scan")}
    for engine, r in results.items():
        assert r.migrations == 1, engine
        assert r.final_region[0] == 1, engine       # ended in the clean one
        assert r.migration_carbon_g > 0, engine
    assert_geo_results_identical(results["scalar"], results["vector"],
                                 "greedy-gap scalar-vs-vector")
    assert_geo_results_identical(results["scalar"], results["scan"],
                                 "greedy-gap scalar-vs-scan")


def test_geo_flex_beats_static_with_migration_costs_charged(world):
    geo, mci, jobs = world
    static = simulate(jobs, mci, geo, GeoStaticPolicy(), horizon=WEEK)
    flex = simulate(jobs, mci, geo, GeoFlexPolicy(), horizon=WEEK)
    assert flex.migrations > 0 and flex.migration_carbon_g > 0
    assert flex.carbon_g < static.carbon_g


def test_bad_region_index_rejected(world):
    geo, mci, jobs = world

    @dataclasses.dataclass
    class BadPolicy:
        name: str = "bad"

        def on_window_start(self, mci, t0, horizon, jobs, geo):
            pass

        def decide_geo(self, t, active, mci, geo):
            return geo.capacity_vec(), {a.job.job_id: (99, a.job.k_min)
                                        for a in active}

        def on_completion(self, t, job, violated):
            pass

    with pytest.raises(ValueError, match="region"):
        simulate(jobs[:5], mci, geo, BadPolicy(), horizon=24)


def test_geo_cluster_validation_and_split():
    geo = GeoCluster.split(7, REGIONS3)
    assert geo.capacities == (3, 2, 2) and geo.capacity == 7
    assert [geo.home_region(i) for i in range(5)] == [0, 1, 2, 0, 1]
    sub = geo.region_cluster(1)
    assert sub.capacity == 2 and sub.queues == geo.queues
    with pytest.raises(ValueError, match="align"):
        GeoCluster(regions=REGIONS2, capacities=(4,), queues=geo.queues)
    with pytest.raises(ValueError, match="positive"):
        GeoCluster(regions=REGIONS2, capacities=(4, 0), queues=geo.queues)


def test_multi_region_service_validation():
    mci = MultiRegionCarbonService.synthetic(REGIONS2, 48, seed=1)
    assert mci.n_regions == 2 and len(mci) == 48
    assert mci.index("california") == 1
    assert mci.service("california") is mci.services[1]
    assert mci.ci_vec(0).shape == (2,)
    assert mci.forecast_matrix(0, 24).shape == (2, 24)
    assert 0.0 <= mci.rank_vec(5).min() <= 1.0
    assert mci.cleanest(3) == int(np.argmin(mci.ci_vec(3)))
    with pytest.raises(ValueError, match="texas"):
        mci.index("texas")
    with pytest.raises(ValueError, match="equal length"):
        MultiRegionCarbonService(
            REGIONS2, (CarbonService.synthetic("ontario", 24),
                       CarbonService.synthetic("sweden", 48)))
    with pytest.raises(ValueError, match="duplicate"):
        MultiRegionCarbonService.synthetic(("ontario", "ontario"), 24)


def test_geo_cluster_requires_multi_region_service(world):
    geo, mci, jobs = world
    with pytest.raises(TypeError, match="MultiRegionCarbonService"):
        simulate(jobs, CarbonService.synthetic("ontario", WEEK * 2), geo,
                 GeoStaticPolicy(), horizon=WEEK)


# --- MigrationModel edge cases (ISSUE-4 satellite) ---------------------------


@dataclasses.dataclass
class _EchoRegionPolicy:
    """Explicitly re-asserts every job's *current* region each slot — a
    same-region 'migration' request, which must be a no-op."""

    name: str = "echo-region"

    def on_window_start(self, mci, t0, horizon, jobs, geo):
        pass

    def decide_geo(self, t, active, mci, geo):
        m_vec = geo.capacity_vec()
        used = np.zeros(geo.n_regions, dtype=np.int64)
        alloc = {}
        for a in active:
            if a.done or a.migrating:
                continue
            r, k = a.region, a.job.k_min
            if used[r] + k <= m_vec[r]:
                alloc[a.job.job_id] = (r, k)
                used[r] += k
        return m_vec, alloc

    def on_completion(self, t, job, violated):
        pass


@dataclasses.dataclass
class _OneMovePolicy:
    """Runs every job in its current region, except one forced move of
    region 0 -> 1 at slot ``move_at`` (checkpoint accounting probe)."""

    move_at: int = 3
    name: str = "one-move"

    def on_window_start(self, mci, t0, horizon, jobs, geo):
        pass

    def decide_geo(self, t, active, mci, geo):
        alloc = {}
        for a in active:
            if a.done or a.migrating:
                continue
            if t == self.move_at and a.region == 0 and a.started:
                alloc[a.job.job_id] = (1, a.job.k_min)
            else:
                alloc[a.job.job_id] = (a.region, a.job.k_min)
        return geo.capacity_vec(), alloc

    def on_completion(self, t, job, violated):
        pass


class TestMigrationEdgeCases:
    def test_zero_size_job_floors_at_min_gb_and_base_slots(self):
        mm = MigrationModel(base_slots=2, slots_per_length=0.05,
                            energy_kwh_per_gb=0.1, min_gb=1.5)
        zero = Job(job_id=0, arrival=0, length=0.0, queue=0, delay=6,
                   profile=np.ones(1), comm_size=0.0)
        assert mm.slots(zero) == 2                      # no length term
        assert mm.data_gb(zero) == 1.5                  # payload floored
        assert mm.energy_kwh(zero) == pytest.approx(0.15)
        assert mm.carbon_g(zero, 0.0) == 0.0            # free at zero CI

    def test_same_region_request_is_a_noop(self, world):
        geo, mci, jobs = world
        echo = simulate(jobs, mci, geo, _EchoRegionPolicy(), horizon=WEEK)
        static = simulate(jobs, mci, geo, GeoStaticPolicy(), horizon=WEEK)
        assert echo.migrations == 0
        assert echo.migration_carbon_g == 0.0
        assert_geo_results_identical(echo, static, "echo-vs-static")

    def test_checkpoint_restore_charged_at_destination_ci(self):
        ci_a, ci_b = 100.0, 400.0
        mci = MultiRegionCarbonService(
            ("cheap", "dirty"),
            (CarbonService(trace=np.full(24 * 10, ci_a)),
             CarbonService(trace=np.full(24 * 10, ci_b))))
        mm = MigrationModel(base_slots=1, slots_per_length=0.02,
                            energy_kwh_per_gb=0.05, min_gb=1.0)
        geo = GeoCluster(regions=("cheap", "dirty"), capacities=(2, 2),
                         queues=ClusterConfig.default(4).queues,
                         migration=mm)
        job = Job(job_id=0, arrival=0, length=10.0, queue=2, delay=48,
                  profile=np.ones(1), comm_size=4.0)
        mig_slots = mm.slots(job)               # 1 + ceil(0.2) = 2
        assert mig_slots == 2
        for engine in ("scalar", "vector"):
            r = simulate([job], mci, geo, _OneMovePolicy(move_at=3),
                         horizon=WEEK, engine=engine)
            assert r.migrations == 1
            # transfer energy billed once, at the DESTINATION's CI on the
            # initiation slot
            assert r.migration_carbon_g \
                == pytest.approx(mm.energy_kwh(job) * ci_b)
            # the checkpoint/restore window suspends the job (waiting
            # budget burned, no progress, no energy in either region)
            assert r.wait_slots[0] == mig_slots
            # 3 run slots, 2 suspended, then 7 slots of remaining work
            assert r.completion[0] == 3 + mig_slots + 7 - 1
            # 3 pre-move slots at the source CI; the rest (7 slots of
            # remaining work + transfer) billed in the destination
            assert r.region_energy_kwh[0] == pytest.approx(3.0)
            assert r.region_carbon_g[0] == pytest.approx(3.0 * ci_a)
            assert r.region_energy_kwh[1] \
                == pytest.approx(7.0 + mm.energy_kwh(job))
            assert r.region_carbon_g[1] \
                == pytest.approx((7.0 + mm.energy_kwh(job)) * ci_b)
            assert r.final_region[0] == 1


# --- experiment API threading ------------------------------------------------


TINY_GEO = dict(regions=REGIONS2, capacity=10, learn_weeks=1, seed=3,
                family="alibaba")


class TestGeoScenario:
    def test_materialize_builds_geo_world(self):
        mat = Scenario(**TINY_GEO).materialize()
        assert mat.is_geo
        assert mat.geo.regions == REGIONS2
        assert sum(mat.geo.capacities) == 10
        assert mat.mci.n_regions == 2
        assert mat.ci is mat.mci.service(0)     # single-region anchor
        assert len(mat.mci) >= mat.scenario.hours

    def test_single_region_scenario_unchanged(self):
        mat = Scenario(capacity=10, learn_weeks=1, seed=3).materialize()
        assert not mat.is_geo and mat.geo is None and mat.mci is None

    def test_validation(self):
        with pytest.raises(ValueError, match="nowhere"):
            Scenario(regions=("california", "nowhere"))
        with pytest.raises(ValueError, match=">= 2"):
            Scenario(regions=("california",))

    def test_round_trip_with_migration_model(self):
        import json
        sc = Scenario(**TINY_GEO,
                      migration=MigrationModel(base_slots=2))
        rt = Scenario.from_dict(json.loads(json.dumps(sc.to_dict())))
        assert rt.regions == sc.regions
        assert rt.migration.base_slots == 2
        assert rt == sc


class TestGeoRegistryAndDriver:
    def test_geo_policies_rejected_on_single_region_scenario(self):
        with pytest.raises(ValueError, match="regions"):
            run(Scenario(capacity=8, learn_weeks=1), ["geo-flex"])

    def test_single_region_policies_rejected_on_geo_scenario(self):
        with pytest.raises(ValueError, match="single-region"):
            run(Scenario(**TINY_GEO), ["carbonflex"])

    def test_driver_defaults_to_geo_set_and_flex_wins(self):
        res = run(Scenario(**TINY_GEO))
        assert res.policies == DEFAULT_GEO_POLICIES
        for n in DEFAULT_GEO_POLICIES:
            assert (res.weekly[n][0].completion >= 0).all(), n
        assert res.savings("geo-flex", "geo-static") > 0

    def test_context_carries_geo_objects(self):
        mat = Scenario(**TINY_GEO).materialize()
        ctx = prepare_context(mat, ["geo-static"])
        assert ctx.geo is mat.geo and ctx.mci is mat.mci
        pol = make_policy("geo-flex", ctx)
        assert pol.name == "geo-flex"


class TestGeoSweep:
    def test_geo_sweep_defaults_baseline_and_labels(self):
        sw = Sweep(base=Scenario(**TINY_GEO), seeds=[3, 4],
                   policies=["geo-greedy", "geo-flex"])
        sr = sw.run()
        assert sr.baseline == "geo-static"
        rows = sr.rows()
        assert {r["policy"] for r in rows} == {"geo-static", "geo-greedy",
                                               "geo-flex"}
        assert all(r["region"] == "south-australia+california" for r in rows)
        assert all("migrations" in r for r in rows)
        flex = [r for r in rows if r["policy"] == "geo-flex"]
        assert all(r["savings_pct"] > 0 for r in flex)
        payload = sr.to_json()
        from repro.experiment import SweepResult
        restored = SweepResult.from_json(payload)
        assert restored.to_json() == payload

    def test_geo_base_rejects_single_region_axis(self):
        sw = Sweep(base=Scenario(**TINY_GEO), regions=["ontario"],
                   policies=["geo-static"])
        with pytest.raises(ValueError, match="seeds"):
            sw.run()
