"""ISSUE 10: receding-horizon execution phase — MPC config validation,
three-way engine parity for the new policies, registry pins, and the
estimated-oracle mode.

The MPC policies precompute all planning state into integer decision
tables at window start, so scalar/vector/scan must agree bit-for-bit on
every ``SimResult`` field — the same contract the older policies pin in
``test_engine_parity.py`` — with and without fault injection and noisy
forecast models."""
import dataclasses
import logging

import numpy as np
import pytest

from repro.core import (CarbonFlexPolicy, CarbonService, ClusterConfig,
                        KnowledgeBase, NoisyForecast, QuantileForecast,
                        baselines, learn_window, simulate)
from repro.core.mpc import (CarbonFlexMPCPolicy, CarbonFlexScalePolicy,
                            EstimatedOraclePolicy, MPCConfig)
from repro.core.scan_engine import native_kind
from repro.core.simulator import FaultModel, SimCase, simulate_many
from repro.experiment.registry import PolicyContext, make_policy
from repro.traces import TraceSpec, generate_trace

WEEK = 24 * 7
CAP = 16


@pytest.fixture(scope="module")
def world():
    cluster = ClusterConfig.default(capacity=CAP)
    ci = CarbonService.synthetic("south-australia", WEEK * 3 + 24 * 30,
                                 seed=31)
    spec = TraceSpec(family="azure", hours=WEEK * 2, capacity=CAP, seed=32)
    jobs = generate_trace(spec, cluster.queues)
    hist = [j for j in jobs if j.arrival < WEEK]
    ev = [j for j in jobs if WEEK <= j.arrival < WEEK * 2]
    kb = KnowledgeBase()
    learn_window(kb, hist, ci, 0, WEEK, cluster, backend="numpy")
    return cluster, ci, hist, ev, kb


def assert_results_identical(a, b, ctx=""):
    assert a.carbon_g == b.carbon_g, ctx
    assert a.energy_kwh == b.energy_kwh, ctx
    np.testing.assert_array_equal(a.completion, b.completion, err_msg=ctx)
    np.testing.assert_array_equal(a.violations, b.violations, err_msg=ctx)
    np.testing.assert_array_equal(a.wait_slots, b.wait_slots, err_msg=ctx)
    assert len(a.slots) == len(b.slots), ctx
    for la, lb in zip(a.slots, b.slots):
        assert la == lb, f"{ctx}: slot {la.slot}"


# --- config ------------------------------------------------------------------


def test_mpc_config_validation():
    with pytest.raises(ValueError):
        MPCConfig(horizon=-1)
    with pytest.raises(ValueError):
        MPCConfig(replan_every=0)
    with pytest.raises(ValueError):
        MPCConfig(max_done=0)
    with pytest.raises(ValueError):
        MPCConfig(clean_frac=1.5)
    # horizon=0 is a valid *config* (the registry maps it to the plain
    # policy) but not a valid planner
    with pytest.raises(ValueError):
        CarbonFlexMPCPolicy(cfg=MPCConfig(horizon=0))


def test_mpc_config_round_trip():
    cfg = MPCConfig(horizon=24, replan_every=6, percentile=75.0,
                    clean_frac=0.4, scale_rho=0.3)
    assert MPCConfig.from_dict(cfg.to_dict()) == cfg


# --- three-way engine parity -------------------------------------------------

FORECASTS = {"perfect": None,
             "noisy": NoisyForecast(sigma=0.3, seed=5),
             "quantile": QuantileForecast(sigma=0.3, seed=5, members=5)}
CONFIGS = {"default": MPCConfig(),
           # scale_rho forces genuinely scaled cells for carbonflex-scale
           # (the learned rho median licenses none on this workload)
           "short-coarse": MPCConfig(horizon=24, replan_every=6,
                                     percentile=75.0, clean_frac=0.4,
                                     scale_rho=0.3)}


def _mk(policy_name, cfg, kb, hist):
    if policy_name == "carbonflex-scale":
        p = CarbonFlexScalePolicy(cfg=cfg, kb=kb)
    else:
        p = CarbonFlexMPCPolicy(cfg=cfg)
    p.warm_start(hist)
    return p


@pytest.mark.parametrize("policy_name", ["carbonflex-mpc",
                                         "carbonflex-scale"])
@pytest.mark.parametrize("cfg_name", sorted(CONFIGS))
@pytest.mark.parametrize("forecast", sorted(FORECASTS))
def test_three_way_parity(world, policy_name, cfg_name, forecast):
    cluster, ci, hist, ev, kb = world
    ci_f = (ci if FORECASTS[forecast] is None
            else dataclasses.replace(ci, model=FORECASTS[forecast]))
    mk = lambda: _mk(policy_name, CONFIGS[cfg_name], kb, hist)  # noqa: E731
    rs = simulate(ev, ci_f, cluster, mk(), t0=WEEK, horizon=WEEK,
                  engine="scalar")
    for engine in ("vector", "scan"):
        rv = simulate(ev, ci_f, cluster, mk(), t0=WEEK, horizon=WEEK,
                      engine=engine)
        assert_results_identical(
            rs, rv, f"{policy_name}/{cfg_name}/{forecast}/{engine}")
        assert (rv.completion >= 0).all()


@pytest.mark.parametrize("policy_name", ["carbonflex-mpc",
                                         "carbonflex-scale"])
def test_three_way_parity_under_faults(world, policy_name):
    """Faulted cases delegate scan -> vector; all three must still agree."""
    cluster, ci, hist, ev, kb = world
    mk = lambda: _mk(policy_name, MPCConfig(), kb, hist)  # noqa: E731
    mk_faults = lambda: FaultModel(straggler_rate=0.15, failure_rate=0.05,  # noqa: E731
                                   seed=9)
    rs = simulate(ev, ci, cluster, mk(), t0=WEEK, horizon=WEEK,
                  engine="scalar", faults=mk_faults())
    for engine in ("vector", "scan"):
        rv = simulate(ev, ci, cluster, mk(), t0=WEEK, horizon=WEEK,
                      engine=engine, faults=mk_faults())
        assert_results_identical(rs, rv, f"{policy_name}+faults/{engine}")


def test_mpc_beats_greedy_carbonflex(world):
    """The point of the PR: receding-horizon planning burns less carbon
    than greedy per-slot mimicry on the same world."""
    cluster, ci, hist, ev, kb = world
    base = simulate(ev, ci, cluster, baselines.CarbonAgnosticPolicy(),
                    t0=WEEK, horizon=WEEK)
    greedy = simulate(ev, ci, cluster, CarbonFlexPolicy(kb),
                      t0=WEEK, horizon=WEEK)
    mpc = _mk("carbonflex-mpc", MPCConfig(), kb, hist)
    r = simulate(ev, ci, cluster, mpc, t0=WEEK, horizon=WEEK, engine="scan")
    assert r.savings_vs(base) > greedy.savings_vs(base)


# --- scan-native dispatch ----------------------------------------------------


def test_native_kind_mpc(world):
    cluster, ci, hist, ev, kb = world
    mpc = _mk("carbonflex-mpc", MPCConfig(), kb, hist)
    scale = _mk("carbonflex-scale", MPCConfig(), kb, hist)
    assert native_kind(mpc, cluster, None) == "mpc"
    assert native_kind(scale, cluster, None) == "mpc-scale"
    # faulted cases delegate; greedy carbonflex was never scan-native
    assert native_kind(mpc, cluster, FaultModel(seed=1)) is None
    assert native_kind(CarbonFlexPolicy(kb), cluster, None) is None


def test_scale_with_recorder_delegates_and_matches(world):
    """mpc-scale + a decision-trace recorder runs through the vector
    engine (scan slot events assume k == k_min) — bit-identically."""
    from repro.telemetry import MemoryRecorder, Telemetry

    cluster, ci, hist, ev, kb = world
    rs = simulate(ev, ci, cluster,
                  _mk("carbonflex-scale", MPCConfig(), kb, hist),
                  t0=WEEK, horizon=WEEK, engine="scalar")
    tel = Telemetry(recorder=MemoryRecorder()).for_run("scale")
    rv = simulate(ev, ci, cluster,
                  _mk("carbonflex-scale", MPCConfig(), kb, hist),
                  t0=WEEK, horizon=WEEK, engine="scan", telemetry=tel)
    assert_results_identical(rs, rv, "scale+recorder")
    assert len(tel.recorder) > 0


def test_scan_batch_logs_delegation_once(world, caplog):
    """A scan batch with non-native cells reports the silent vector
    fallback exactly once per dispatch (ISSUE 10 S2)."""
    cluster, ci, hist, ev, kb = world
    cases = [SimCase(jobs=ev, ci=ci, cluster=cluster,
                     policy=CarbonFlexPolicy(kb), t0=WEEK, horizon=WEEK,
                     engine="scan", label="carbonflex"),
             SimCase(jobs=ev, ci=ci, cluster=cluster,
                     policy=_mk("carbonflex-mpc", MPCConfig(), kb, hist),
                     t0=WEEK, horizon=WEEK, engine="scan", label="mpc")]
    with caplog.at_level(logging.INFO, logger="repro.core.scan_engine"):
        simulate_many(cases)
    recs = [r for r in caplog.records if "delegated" in r.getMessage()]
    assert len(recs) == 1
    assert "carbonflex" in recs[0].getMessage()
    assert "mpc x" not in recs[0].getMessage()


# --- registry pins -----------------------------------------------------------


def _ctx(world, mpc_cfg=None):
    cluster, ci, hist, ev, kb = world
    return PolicyContext(cluster=cluster, ci=ci, history=list(hist),
                         kb=kb, mpc=mpc_cfg)


def test_registry_horizon0_pins_to_plain_carbonflex(world):
    """MPCConfig(horizon=0) degenerates to greedy mimicry: the registry
    hands back a plain CarbonFlexPolicy (so `carbonflex-mpc` at horizon 0
    is bit-identical to `carbonflex`), keeping the knob ladder anchored."""
    cluster, ci, hist, ev, kb = world
    pol = make_policy("carbonflex-mpc", _ctx(world, MPCConfig(horizon=0)))
    assert type(pol) is CarbonFlexPolicy
    assert pol.name == "carbonflex-mpc"
    ra = simulate(ev, ci, cluster, pol, t0=WEEK, horizon=WEEK)
    rb = simulate(ev, ci, cluster, CarbonFlexPolicy(kb), t0=WEEK,
                  horizon=WEEK)
    assert_results_identical(ra, rb, "horizon0-pin")


def test_registry_builds_mpc_family(world):
    mpc = make_policy("carbonflex-mpc", _ctx(world))
    scale = make_policy("carbonflex-scale", _ctx(world))
    est = make_policy("oracle-estimated", _ctx(world))
    assert type(mpc) is CarbonFlexMPCPolicy
    assert type(scale) is CarbonFlexScalePolicy
    assert type(est) is EstimatedOraclePolicy
    # warm-started from ctx.history, not the bare prior
    assert any(len(h) > 1 for h in mpc._hist.values())
    cfg = MPCConfig(horizon=24, replan_every=6)
    assert make_policy("carbonflex-mpc", _ctx(world, cfg)).cfg == cfg


# --- estimated oracle (S1) ---------------------------------------------------


def test_estimated_oracle_runs_and_saves(world):
    cluster, ci, hist, ev, kb = world
    base = simulate(ev, ci, cluster, baselines.CarbonAgnosticPolicy(),
                    t0=WEEK, horizon=WEEK)
    pol = EstimatedOraclePolicy()
    pol.warm_start(hist)
    rs = simulate(ev, ci, cluster, pol, t0=WEEK, horizon=WEEK,
                  engine="scalar")
    assert (rs.completion >= 0).all()
    assert rs.savings_vs(base) > 0
    # not packed-safe: the vector/scan engines take the per-slot decide
    # path and must agree with the scalar reference
    for engine in ("vector", "scan"):
        pol2 = EstimatedOraclePolicy()
        pol2.warm_start(hist)
        rv = simulate(ev, ci, cluster, pol2, t0=WEEK, horizon=WEEK,
                      engine=engine)
        assert_results_identical(rs, rv, f"oracle-estimated/{engine}")
