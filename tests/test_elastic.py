"""Elastic runtime tests: checkpoint manager, rescale, faults, compression."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, reduced
from repro.elastic import ElasticTrainer, RescalePlan, make_compressor
from repro.launch.mesh import make_mesh
from repro.train import (CheckpointManager, DataConfig, OptimizerConfig,
                         SyntheticLM)

pytestmark = pytest.mark.slow        # real train/rescale steps on CPU


class TestCheckpointManager:
    def test_roundtrip(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(3.5)}}
        cm.save(7, tree, blocking=True)
        assert cm.latest_step() == 7
        out = cm.restore(jax.eval_shape(lambda: tree))
        np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
        assert float(out["b"]["c"]) == 3.5

    def test_keep_policy_gc(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep=2)
        tree = {"x": jnp.zeros(3)}
        for s in [1, 2, 3, 4]:
            cm.save(s, tree, blocking=True)
        assert cm.steps() == [3, 4]

    def test_partial_write_ignored(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(5, {"x": jnp.ones(2)}, blocking=True)
        os.makedirs(tmp_path / "tmp.step_000000009")   # crashed writer
        cm2 = CheckpointManager(str(tmp_path))
        assert cm2.latest_step() == 5
        assert not os.path.exists(tmp_path / "tmp.step_000000009")

    def test_restore_with_new_sharding(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        cm.save(1, tree, blocking=True)
        mesh = make_mesh((1,), ("data",))
        sh = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec("data"))
        out = cm.restore(jax.eval_shape(lambda: tree), shardings={"w": sh})
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(tree["w"]))
        assert out["w"].sharding == sh


class TestCompression:
    @pytest.mark.parametrize("kind", ["int8", "topk"])
    def test_error_feedback_unbiased_over_time(self, kind):
        comp = make_compressor(kind, ratio=0.25)
        rng = np.random.default_rng(0)
        true_sum = np.zeros((8, 8))
        applied_sum = np.zeros((8, 8))
        ef = None
        for _ in range(60):
            g = rng.normal(size=(8, 8)).astype(np.float32)
            true_sum += g
            sent, ef = comp({"g": jnp.asarray(g)}, ef)
            applied_sum += np.asarray(sent["g"])
        resid = np.abs(true_sum - applied_sum).max()
        # cumulative applied gradient tracks the true sum to within a
        # BOUNDED error-feedback residual (it does not grow with steps),
        # while the cumulative gradient magnitude itself keeps growing.
        per_step_mag = 0.8   # E|N(0,1)|
        assert resid < 8 * per_step_mag          # bounded, ~O(1) steps' worth
        assert resid < 0.2 * 60 * per_step_mag   # far below unfed drift

    def test_int8_wire_dtype(self):
        from repro.elastic.compression import _int8_roundtrip
        g = jnp.asarray(np.random.default_rng(1).normal(size=(32,)), jnp.float32)
        out = _int8_roundtrip(g)
        assert float(jnp.abs(out - g).max()) < float(jnp.abs(g).max()) / 64


class TestElasticTrainer:
    def _mk(self, tmp_path, **kw):
        cfg = reduced(ARCHS["stablelm-1.6b"])
        data = SyntheticLM(DataConfig(batch=4, seq_len=32,
                                      vocab_size=cfg.vocab_size, seed=3))
        return ElasticTrainer(cfg, data, OptimizerConfig(total_steps=60),
                              str(tmp_path / "ckpt"), **kw)

    def test_elastic_plan_rescales(self, tmp_path):
        tr = self._mk(tmp_path)
        out = tr.run([RescalePlan(k=1, steps=3), RescalePlan(k=0, steps=5),
                      RescalePlan(k=1, steps=3)], checkpoint_every=2)
        assert out["final_step"] == 6
        assert len(out["losses"]) == 6
        assert np.isfinite(out["losses"]).all()

    def test_fault_recovery(self, tmp_path):
        tr = self._mk(tmp_path)
        out = tr.run([RescalePlan(k=1, steps=6)], checkpoint_every=2,
                     fault_at=4)
        assert out["recoveries"] >= 1
        assert out["final_step"] == 6          # work completed despite fault

    def test_resume_from_checkpoint(self, tmp_path):
        tr = self._mk(tmp_path)
        tr.run([RescalePlan(k=1, steps=4)], checkpoint_every=2)
        # new trainer picks up from the checkpoint directory
        tr2 = self._mk(tmp_path)
        out = tr2.run([RescalePlan(k=1, steps=2)])
        assert out["final_step"] == 6
        assert tr2.recoveries >= 1

    def test_compression_trains(self, tmp_path):
        tr = self._mk(tmp_path, compression=make_compressor("int8"))
        out = tr.run([RescalePlan(k=1, steps=4)])
        assert np.isfinite(out["losses"]).all()
